"""Fault tolerance (ISSUE-10): deterministic chaos injection,
cancellation/deadlines, replica supervision and in-flight failover.

Covers the acceptance surface: FaultPlan trigger windows + replica
scoping; cancellation at every phase (waiting / mid-prefill /
mid-decode / swapped-out) releasing pages with ``check_invariants``
holding and sibling streams bit-identical (a hypothesis sweep in CI,
a deterministic slice locally); hard deadlines retiring with
``finish_reason="timeout"``; injected pool/swap failures degrading
without changing any token stream; a replica crash mid-stream recovered
by the supervisor with failed-over streams token-identical to an
uninjected run and the recovery counters ticking; the server's 503 +
``Retry-After`` when every replica is down; and a client disconnect
cancelling its request and returning the pool to its pre-admission
free-page level.
"""

import asyncio
import json
import threading
import time

import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_smoke
from repro.models import LM
from repro.serve import (FaultPlan, FaultSpec, Request, ServeEngine,
                         StreamEvent)
from repro.serve.frontend import (CompletionRequest, Replica, Router,
                                  Server, Supervisor, sse_decode)

SAMPLED = dict(temperature=0.9, top_k=20)   # key contract load-bearing


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


def _engine(tiny, **kw):
    model, params = tiny
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(model, params, **kw)


def _reqs(vocab, n=8, max_new=(2, 5, 9, 14), **kw):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=(4, 7, 12)[i % 3],
                                    dtype=np.int32),
                max_new_tokens=max_new[i % len(max_new)], **kw)
        for i in range(n)
    ]


# ======================================================================
# FaultPlan: parsing, trigger windows, replica scoping
# ======================================================================
def test_fault_spec_parse_roundtrip():
    s = FaultSpec.parse("replica_worker:after=3,count=2,replica=r1")
    assert (s.site, s.after, s.count, s.replica) == \
           ("replica_worker", 3, 2, "r1")
    assert FaultSpec.parse("slow_burst:delay_s=0.25").delay_s == 0.25
    with pytest.raises(ValueError):
        FaultPlan([FaultSpec.parse("nonsense")])
    with pytest.raises(ValueError):
        FaultSpec.parse("engine_step:bogus=1")
    with pytest.raises(ValueError):
        FaultPlan([FaultSpec("engine_step", count=0)])


def test_fault_plan_fire_window():
    plan = FaultPlan([FaultSpec("pool_alloc", after=2, count=2)])
    hits = [plan.hit("pool_alloc") is not None for _ in range(6)]
    assert hits == [False, False, True, True, False, False]
    assert plan.fired == {"pool_alloc": 2}
    assert plan.hit("swap_error") is None       # other sites untouched
    assert not FaultPlan()                      # empty plan is falsy


def test_fault_plan_replica_scoping():
    plan = FaultPlan([FaultSpec("replica_worker", after=1, replica="r1")])
    # r0 passes never count toward an r1-scoped spec
    assert all(plan.hit("replica_worker", "r0") is None for _ in range(5))
    assert plan.hit("replica_worker", "r1") is None       # pass 1 = after
    assert plan.hit("replica_worker", "r1") is not None   # pass 2 fires
    assert plan.hit("replica_worker", "r1") is None       # quiet again


# ======================================================================
# cancellation: any phase, zero leaks, siblings untouched
# ======================================================================
def _run_session(eng, reqs, cancel_at=None, seed=0, max_steps=400):
    """Drive a session to completion, cancelling ``cancel_at[uid]`` at
    that step index.  Returns (per-uid token lists, terminal events,
    uids whose cancel actually landed)."""
    cancel_at = dict(cancel_at or {})
    session = eng.session(seed=seed)
    full = eng.pool.free_pages                # post-reset, pre-admission
    for r in reqs:
        session.submit(r)
    toks, final, cancelled = {}, {}, set()
    step = 0
    while session.has_work():
        assert step < max_steps, "session failed to converge"
        for uid, at in list(cancel_at.items()):
            if at <= step:
                ev = session.cancel(uid)
                del cancel_at[uid]
                if ev is not None:
                    cancelled.add(uid)
                    final[uid] = ev
        for ev in session.step():
            toks.setdefault(ev.uid, []).extend(ev.tokens)
            if ev.finished:
                final[ev.uid] = ev
        eng.pool.check_invariants()
        step += 1
    assert eng.pool.free_pages == full, "cancel leaked KV pages"
    return toks, final, cancelled


def test_cancel_every_phase_releases_pages(tiny):
    """Deterministic slice of the sweep: cancel one waiting, one
    mid-prefill and one mid-decode request; invariants hold each step,
    the pool returns to its pre-admission free level, survivors stream
    bit-identically, and the cancelled counter ticks."""
    eng = _engine(tiny, prefix_cache=False, **SAMPLED)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=6)
    base = {r.uid: list(r.tokens) for r in eng.generate(reqs, seed=0)}

    session = eng.session(seed=0)
    full = eng.pool.free_pages
    for r in reqs:
        session.submit(r)
    # uid 5 is still WAITING (4 slots); cancel before any step
    ev = session.cancel(5)
    assert ev.finished and ev.finish_reason == "cancelled"
    assert ev.result.tokens.size == 0
    evs = session.step()                      # uid 2 (12-tok prompt) is
    ev2 = session.cancel(2)                   # mid-prefill/first-decode
    assert ev2 is not None and ev2.finish_reason == "cancelled"
    eng.pool.check_invariants()
    toks = {}
    for e in evs:
        toks.setdefault(e.uid, []).extend(e.tokens)
    for _ in range(3):
        if session.has_work():
            for e in session.step():
                toks.setdefault(e.uid, []).extend(e.tokens)
    ev0 = session.cancel(0)                   # mid-decode (or finished)
    while session.has_work():
        for e in session.step():
            toks.setdefault(e.uid, []).extend(e.tokens)
        eng.pool.check_invariants()
    assert eng.pool.free_pages == full
    assert session.cancel(999) is None        # unknown uid
    survivors = {1, 3, 4} | ({0} if ev0 is None else set())
    for uid in survivors:
        assert toks[uid] == base[uid], f"uid {uid} stream changed"
    n_cancel = 2 + (ev0 is not None)
    assert eng.stats["cancelled"] >= n_cancel


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_cancel_sweep_random_phases(tiny, data):
    """Hypothesis sweep (CI): random uids cancelled at random steps —
    including swapped-out victims (a 13-page pool forces preemption) —
    never leak pages, never violate pool invariants, and never change a
    surviving sibling's stream."""
    eng = _engine(tiny, prefix_cache=False, num_pages=13, **SAMPLED)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=6)
    base = {r.uid: list(r.tokens) for r in eng.generate(reqs, seed=0)}
    uids = data.draw(st.lists(st.integers(0, 5), min_size=1, max_size=4,
                              unique=True))
    cancel_at = {u: data.draw(st.integers(0, 14)) for u in uids}
    toks, final, cancelled = _run_session(eng, reqs, cancel_at)
    for uid in set(base) - cancelled:
        assert toks.get(uid, []) == base[uid], f"uid {uid} stream changed"
        assert final[uid].finish_reason in ("stop", "length")
    for uid in cancelled:
        assert final[uid].finish_reason == "cancelled"


def test_hard_deadline_retires_with_timeout(tiny):
    """An expired hard deadline retires at the next sync with
    ``finish_reason="timeout"`` and frees capacity; an ordering-only
    deadline (the pre-ISSUE-10 field) never expires; siblings finish
    with their exact tokens."""
    eng = _engine(tiny, prefix_cache=False, **SAMPLED)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=4)
    base = {r.uid: list(r.tokens) for r in eng.generate(reqs, seed=0)}
    now = time.monotonic()
    reqs = _reqs(tiny[0].cfg.vocab_size, n=4)
    reqs[1].deadline, reqs[1].deadline_hard = now - 0.001, True
    reqs[2].deadline = 100.0                  # ordering-only: tiny abs
    toks, final, _ = _run_session(eng, reqs)  # value, but never expires
    assert final[1].finish_reason == "timeout"
    assert list(final[1].result.tokens) == []
    for uid in (0, 2, 3):
        assert toks[uid] == base[uid]
    assert eng.stats["deadline_exceeded"] == 1


# ======================================================================
# injected pool/swap failures: graceful degrade, identical streams
# ======================================================================
def test_pool_alloc_fault_degrades_without_stream_change(tiny):
    ref = [list(r.tokens) for r in
           _engine(tiny, prefix_cache=False, **SAMPLED).generate(
               _reqs(tiny[0].cfg.vocab_size, n=6), seed=0)]
    plan = FaultPlan([FaultSpec("pool_alloc", after=3, count=3)])
    eng = _engine(tiny, prefix_cache=False, faults=plan, **SAMPLED)
    out = [list(r.tokens) for r in
           eng.generate(_reqs(tiny[0].cfg.vocab_size, n=6), seed=0)]
    assert plan.fired.get("pool_alloc", 0) >= 1
    assert out == ref
    eng.pool.check_invariants()


def test_swap_error_falls_back_to_recompute(tiny):
    """With the arena failing, preemption degrades to recompute —
    streams stay identical (key contract), nothing leaks."""
    ref = [list(r.tokens) for r in
           _engine(tiny, prefix_cache=False, **SAMPLED).generate(
               _reqs(tiny[0].cfg.vocab_size, n=6), seed=0)]
    plan = FaultPlan([FaultSpec("swap_error", count=1000)])
    eng = _engine(tiny, prefix_cache=False, num_pages=13, faults=plan,
                  **SAMPLED)
    out = [list(r.tokens) for r in
           eng.generate(_reqs(tiny[0].cfg.vocab_size, n=6), seed=0)]
    assert out == ref
    eng.pool.check_invariants()


# ======================================================================
# supervisor: crash detection, restart, in-flight failover
# ======================================================================
def test_supervisor_failover_streams_bit_identical(tiny):
    """Mid-stream replica crash (injected engine_step raise on r0's
    third burst): the supervisor restarts the worker and re-submits its
    in-flight requests; every client stream — including the failed-over
    ones, replay-suppressed — is token-identical to an uninjected run,
    and the restart/failover/recovery series tick."""
    kw = dict(steps_per_sync=2, **SAMPLED)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=6, max_new=(6, 9, 12, 14))
    ref = {r.uid: list(r.tokens)
           for r in _engine(tiny, **kw).generate(reqs, seed=0)}

    plan = FaultPlan([FaultSpec("engine_step", after=2)])
    r0 = Replica(_engine(tiny, faults=plan, **kw), name="r0")
    r1 = Replica(_engine(tiny, **kw), name="r1")
    router = Router([r0, r1])
    sup = Supervisor(router, failover_retries=8)
    lock = threading.Lock()
    toks, done = {}, {}

    def make_cb(uid):
        def cb(ev: StreamEvent) -> None:
            with lock:
                toks.setdefault(uid, []).extend(ev.tokens)
                if ev.finished:
                    done[uid] = ev
        return cb

    try:
        for r in reqs:
            router.submit_request(r, make_cb(r.uid))
        deadline = time.monotonic() + 120
        while len(done) < len(reqs):
            assert time.monotonic() < deadline, \
                f"requests stuck: done={sorted(done)} crashed={r0.crashed!r}"
            sup.check_once()
            time.sleep(0.02)
        recovered = r0.crashed is None and r0.healthy
    finally:
        sup.stop()
        router.close()

    assert plan.fired.get("engine_step", 0) >= 1, "fault never fired"
    assert recovered                             # restarted clean
    with lock:
        for uid, want in ref.items():
            assert toks[uid] == want, f"uid {uid} stream changed"
            assert done[uid].finish_reason in ("stop", "length")
    s0 = r0.engine.m.snapshot()
    assert s0["replica_restarts"] >= 1
    assert s0["failed_over"] >= 1
    rec = r0.engine.obs.metrics.get("serve_recovery_seconds")
    assert rec is not None and sum(c.count for _, c in rec.children()) >= 1


def test_replica_worker_fault_and_restart_idle(tiny):
    """A worker killed while idle (replica_worker site) is detected and
    restarted; the replica serves normally afterwards."""
    plan = FaultPlan([FaultSpec("replica_worker")])
    rep = Replica(_engine(tiny, faults=plan), name="r0")
    router = Router([rep])                   # first worker pass kills it
    sup = Supervisor(router)
    try:
        deadline = time.monotonic() + 30
        while rep.healthy and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not rep.healthy
        assert sup.check_once() == ["r0"]
        assert rep.healthy and rep.crashed is None
        out = rep.complete([CompletionRequest(prompt=[1, 2, 3],
                                              max_tokens=3, uid=0)])
        assert len(out[0].tokens) == 3
    finally:
        sup.stop()
        router.close()


# ======================================================================
# HTTP server: 503 + Retry-After, disconnect cancellation, 504
# ======================================================================
async def _post_raw(host, port, obj):
    body = json.dumps(obj).encode()
    r, w = await asyncio.open_connection(host, port)
    w.write(f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await w.drain()
    data = await r.read()
    w.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), head, rest


def test_server_503_retry_after_when_all_replicas_down(tiny):
    plan = FaultPlan([FaultSpec("replica_worker")])
    rep = Replica(_engine(tiny, faults=plan), name="r0")
    router = Router([rep])

    async def scenario():
        deadline = time.monotonic() + 30
        while rep.healthy and time.monotonic() < deadline:
            await asyncio.sleep(0.01)
        assert not rep.healthy and not rep.draining
        srv = Server(router, port=0)
        host, port = await srv.start()
        status, head, rest = await _post_raw(
            host, port, {"prompt": [1, 2], "max_tokens": 2})
        assert status == 503
        assert b"retry-after:" in head.lower(), head
        if srv._server is not None:
            srv._server.close()
            await srv._server.wait_closed()

    try:
        asyncio.run(scenario())
    finally:
        router.close()


def test_server_504_on_hard_deadline(tiny):
    """A wire ``deadline_ms`` already expired maps to HTTP 504 on the
    non-streaming path."""
    rep = Replica(_engine(tiny), name="r0")
    router = Router([rep])

    async def scenario():
        srv = Server(router, port=0)
        host, port = await srv.start()
        status, head, rest = await _post_raw(
            host, port, {"prompt": [1, 2, 3], "max_tokens": 30,
                         "deadline_ms": 0.0})
        assert status == 504, (status, rest)
        assert b"deadline" in rest
        await srv.shutdown(timeout=30)

    try:
        asyncio.run(scenario())
    finally:
        router.close()


def test_client_disconnect_cancels_and_frees_pages(tiny):
    """Acceptance: a client that vanishes mid-stream triggers
    cancellation — the sequence retires, the cancelled counter ticks,
    and ``free_pages`` returns to its pre-admission level."""
    eng = _engine(tiny, prefix_cache=False, steps_per_sync=1)
    rep = Replica(eng, name="r0")
    router = Router([rep])
    full = eng.pool.free_pages

    async def scenario():
        srv = Server(router, port=0)
        host, port = await srv.start()
        body = json.dumps({"prompt": [1, 2, 3, 4], "max_tokens": 50,
                           "stream": True}).encode()
        r, w = await asyncio.open_connection(host, port)
        w.write(f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await w.drain()
        await r.readuntil(b"\n\n")            # headers + first bytes are
        w.close()                             # flowing... then hang up
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if rep.load == 0 and eng.pool.free_pages == full:
                break
            await asyncio.sleep(0.02)
        assert rep.load == 0, "request not cancelled on disconnect"
        assert eng.pool.free_pages == full, "disconnect leaked pages"
        eng.pool.check_invariants()
        if srv._server is not None:
            srv._server.close()
            await srv._server.wait_closed()

    try:
        asyncio.run(scenario())
        assert eng.stats["cancelled"] >= 1
    finally:
        router.close()


def test_streaming_terminal_chunk_carries_finish_reason(tiny):
    rep = Replica(_engine(tiny), name="r0")
    router = Router([rep])

    async def scenario():
        srv = Server(router, port=0)
        host, port = await srv.start()
        status, head, rest = await _post_raw(
            host, port, {"prompt": [1, 2, 3], "max_tokens": 4,
                         "stream": True})
        assert status == 200
        chunks = sse_decode(rest)
        assert chunks[-1].finished
        assert chunks[-1].finish_reason == "length"
        status, head, rest = await _post_raw(
            host, port, {"prompt": [1, 2, 3], "max_tokens": 4})
        assert json.loads(rest)["finish_reason"] == "length"
        await srv.shutdown(timeout=30)

    try:
        asyncio.run(scenario())
    finally:
        router.close()
