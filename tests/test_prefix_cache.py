"""Page-lifecycle tests for the ISSUE-7 refcounted pool: refcount
invariants under admit/share/CoW/retire/preempt interleavings (a
hypothesis state machine over the allocator + a deterministic seeded
random-walk twin through the real engine), prefix-cache match/cap/
divergence/eviction units, copy-on-write content checks, prefix-on
vs -off and swap-vs-recompute token parity, and a 2x4-mesh subprocess
run proving shared-prefix serving is bit-identical to unshared."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.models import LM
from repro.serve import PagedKVPool, Request, ServeEngine
from repro.serve.kvpool import _tree_get

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


@pytest.fixture(scope="module")
def tiny_random():
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    # sharpen the head so greedy decoding is decisive under f32 jitter
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


def _pool(model, *, num_pages=9, page_size=4, max_slots=3, max_len=32,
          **kw):
    return PagedKVPool(model, num_pages=num_pages, page_size=page_size,
                       max_slots=max_slots, max_len=max_len, **kw)


# ======================================================================
# refcount primitives
# ======================================================================
def test_refcount_alloc_retain_release(tiny_random):
    model, _ = tiny_random
    pool = _pool(model)
    pages = pool.alloc(3)
    assert pages is not None and len(pages) == 3
    assert all(pool.refcount(p) == 1 for p in pages)
    pool.check_invariants()

    pool.retain(pages[0])
    assert pool.refcount(pages[0]) == 2
    pool.release([pages[0]])
    assert pool.refcount(pages[0]) == 1     # still live: one ref left
    assert pool.free_pages == pool.capacity - 3
    pool.release(pages)                      # drops the last refs
    assert pool.free_pages == pool.capacity
    assert all(pool.refcount(p) == 0 for p in pages)
    pool.check_invariants()

    # releasing a freed page is a bug, not a no-op
    with pytest.raises(AssertionError):
        pool.release([pages[0]])
    # so is retaining one (sharing requires a live owner)
    with pytest.raises(AssertionError):
        pool.retain(pages[1])


def test_attach_shares_and_clear_slot_keeps_shared(tiny_random):
    model, _ = tiny_random
    pool = _pool(model)
    pages = pool.alloc(2)
    pool.assign(0, pages)                    # slot 0 owns both
    pool.attach(1, [pages[0]])               # slot 1 shares the first
    assert pool.refcount(pages[0]) == 2
    assert pool.slot_pages(1) == [pages[0]]

    pool.clear_slot(0)                       # slot 0 retires
    # the shared page survives on slot 1's reference; the exclusive
    # page went back to the free list
    assert pool.refcount(pages[0]) == 1
    assert pool.refcount(pages[1]) == 0
    pool.check_invariants()
    pool.clear_slot(1)
    assert pool.free_pages == pool.capacity


def test_ensure_writable_copies_shared_page(tiny_random):
    """CoW data plane: a shared page is copied content-exactly into a
    fresh page, the writer's table repoints, the reader's does not."""
    model, _ = tiny_random
    pool = _pool(model)
    (page,) = pool.alloc(1)
    pool.assign(0, [page])
    pool.attach(1, [page])                   # both slots map the page

    # stamp recognizable contents into every attn leaf of the page
    for path, stacked in pool._attn_paths:
        block = _tree_get(pool.kv, path)
        for k in block:
            v = block[k]
            fill = jax.numpy.full(
                v.shape[1:] if not stacked else (v.shape[0], *v.shape[2:]),
                3.25, v.dtype)
            block[k] = (v.at[page].set(fill) if not stacked
                        else v.at[:, page].set(fill))

    assert pool.ensure_writable(0, 0) is True
    new = pool.slot_pages(0)[0]
    assert new != page and pool.refcount(page) == 1
    assert pool.refcount(new) == 1
    assert pool.slot_pages(1) == [page]      # the reader kept the original
    assert pool.stats["cow_copies"] == 1
    pool.check_invariants()

    # the copy carried the bytes
    for path, stacked in pool._attn_paths:
        block = _tree_get(pool.kv, path)
        for k, v in block.items():
            src = v[page] if not stacked else v[:, page]
            dst = v[new] if not stacked else v[:, new]
            np.testing.assert_array_equal(np.asarray(src), np.asarray(dst))

    # second call: already exclusive, table unchanged, no copy
    assert pool.ensure_writable(0, 0) is True
    assert pool.slot_pages(0)[0] == new
    assert pool.stats["cow_copies"] == 1


def test_ensure_writable_fails_without_pages(tiny_random):
    model, _ = tiny_random
    pool = _pool(model, num_pages=3)         # capacity 2
    (page,) = pool.alloc(1)
    pool.assign(0, [page])
    pool.attach(1, [page])
    pool.alloc(1)                            # drain the free list
    assert pool.ensure_writable(0, 0) is False    # CoW needs a page
    assert pool.slot_pages(0) == [page]           # nothing mutated
    pool.check_invariants()


# ======================================================================
# prefix index: match / cap / divergence / eviction
# ======================================================================
def test_prefix_match_chain_and_cap(tiny_random):
    model, _ = tiny_random
    pool = _pool(model, prefix_cache=True)
    ps = pool.page_size
    toks = np.arange(1, 1 + 3 * ps, dtype=np.int32)     # 3 full pages
    pages = pool.alloc(3)
    pool.prefix.register(toks, pages)
    pool.release(pages)                      # index refs keep them live
    assert all(pool.refcount(p) == 1 for p in pages)

    # full coverage caps at L-1: last matched page becomes the CoW src
    shared, cow, n = pool.prefix.match(toks)
    assert shared == pages[:2] and cow == pages[2] and n == 3 * ps - 1

    # longer prompt with the cached prefix: all 3 pages attach shared
    longer = np.concatenate([toks, [99, 98]]).astype(np.int32)
    shared, cow, n = pool.prefix.match(longer)
    assert shared == pages and cow is None and n == 3 * ps

    # divergence inside page 2 stops the chain after page 1
    div = toks.copy()
    div[ps + 1] = 77
    shared, cow, n = pool.prefix.match(div)
    assert shared == pages[:1] and cow is None and n == ps

    # no match at all
    shared, cow, n = pool.prefix.match(np.asarray([9, 9, 9], np.int32))
    assert shared == [] and cow is None and n == 0


def test_prefix_partial_tail_lcp(tiny_random):
    model, _ = tiny_random
    pool = _pool(model, prefix_cache=True)
    ps = pool.page_size
    # one full page + a 3-token tail, as a retirement would register it
    kv_toks = np.asarray([*range(1, ps + 1), 50, 51, 52], np.int32)
    pages = pool.alloc(2)
    pool.prefix.register(kv_toks, pages, include_partial=True)
    pool.release(pages)

    # prompt sharing 2 of the 3 tail tokens: full page shared, tail
    # page offered as a CoW source covering the LCP
    prompt = np.asarray([*range(1, ps + 1), 50, 51, 60, 61], np.int32)
    shared, cow, n = pool.prefix.match(prompt)
    assert shared == pages[:1] and cow == pages[1] and n == ps + 2

    # LCP is capped at L-1 even through the partial path
    short = np.asarray([*range(1, ps + 1), 50, 51, 52], np.int32)
    shared, cow, n = pool.prefix.match(short)
    assert n <= len(short) - 1


def test_prefix_lru_eviction_feeds_alloc(tiny_random):
    """A short free list evicts index leaves LRU-first from inside
    alloc — and never an entry another chain still hangs off."""
    model, _ = tiny_random
    pool = _pool(model, num_pages=5, prefix_cache=True)   # capacity 4
    ps = pool.page_size
    a = np.arange(1, 1 + 2 * ps, dtype=np.int32)          # chain of 2
    pages = pool.alloc(2)
    pool.prefix.register(a, pages)
    pool.release(pages)
    assert pool.free_pages == 2 and len(pool.prefix) == 2

    # alloc(3) must evict: the LEAF (page 2 of the chain) goes first
    got = pool.alloc(3)
    assert got is not None
    assert pool.stats["prefix_evictions"] >= 1
    pool.check_invariants()
    # the surviving index never references a freed page
    live = [p for p in range(1, pool.num_pages) if pool.refcount(p)]
    shared, cow, n = pool.prefix.match(a)
    for p in shared + ([cow] if cow is not None else []):
        assert p in live


def test_prefix_match_bumps_recency(tiny_random):
    model, _ = tiny_random
    pool = _pool(model, num_pages=6, prefix_cache=True)   # capacity 5
    ps = pool.page_size
    a = np.arange(1, 1 + ps, dtype=np.int32)
    b = np.arange(100, 100 + ps, dtype=np.int32)
    pa = pool.alloc(1)
    pool.prefix.register(a, pa)
    pool.release(pa)
    pb = pool.alloc(1)
    pool.prefix.register(b, pb)
    pool.release(pb)
    # a is older, but matching it makes b the LRU victim
    pool.prefix.match(np.concatenate([a, [7]]).astype(np.int32))
    pool.alloc(4)                     # forces exactly one eviction
    shared, _, _ = pool.prefix.match(np.concatenate([a, [7]]).astype(
        np.int32))
    assert shared == pa               # a survived
    shared, _, _ = pool.prefix.match(np.concatenate([b, [7]]).astype(
        np.int32))
    assert shared == []               # b was evicted


# ======================================================================
# engine integration: parity + savings + preemption flavors
# ======================================================================
def _prefix_requests(vocab, n=8, tail=2, max_new=6):
    shared = np.arange(5, 17, dtype=np.int32)     # 12-token system prefix
    return [
        Request(uid=i,
                prompt=np.concatenate([shared,
                                       np.asarray([20 + i] * tail,
                                                  np.int32)]),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def test_engine_prefix_parity_and_savings(tiny_random):
    """Prefix sharing changes prefill WORK, never tokens: greedy
    streams are bit-identical with the cache on and off, and the stats
    show real savings."""
    model, params = tiny_random
    reqs = _prefix_requests(model.cfg.vocab_size)
    kw = dict(max_batch=4, max_len=64, page_size=8, num_pages=17,
              host_swap_pages=0)
    off = ServeEngine(model, params, prefix_cache=False, **kw)
    base = off.generate(reqs)
    on = ServeEngine(model, params, prefix_cache=True, **kw)
    got = on.generate(reqs)
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert on.stats["prefix_hit_tokens"] > 0
    assert on.stats["prefill_tok"] < off.stats["prefill_tok"]
    assert off.stats["prefix_hit_tokens"] == 0
    on.pool.check_invariants()


def test_engine_prefix_parity_sampled(tiny_random):
    model, params = tiny_random
    reqs = _prefix_requests(model.cfg.vocab_size)
    kw = dict(max_batch=4, max_len=64, page_size=8, num_pages=17,
              temperature=1.0, top_k=5, host_swap_pages=0)
    base = ServeEngine(model, params, prefix_cache=False,
                       **kw).generate(reqs, seed=3)
    on = ServeEngine(model, params, prefix_cache=True, **kw)
    got = on.generate(reqs, seed=3)
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert on.stats["prefix_hit_tokens"] > 0


def _preempt_requests(vocab, n=6):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(1, vocab,
                                    (4, 9, 13)[i % 3]).astype(np.int32),
                max_new_tokens=(22, 9, 26)[i % 3])
        for i in range(n)
    ]


def test_swap_preemption_bit_identical_to_recompute(tiny_random):
    """The acceptance pin: under a pool tight enough to force
    preemption, preserve-KV swap resumes produce EXACTLY the token
    streams recompute produces — and the stats split shows which
    flavor ran."""
    model, params = tiny_random
    reqs = _preempt_requests(model.cfg.vocab_size)
    kw = dict(max_batch=3, max_len=48, page_size=8, num_pages=8,
              prefix_cache=False, steps_per_sync=4)
    rec = ServeEngine(model, params, host_swap_pages=0, **kw)
    base = rec.generate(reqs)
    swp = ServeEngine(model, params, host_swap_pages=None, **kw)
    got = swp.generate(reqs)
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # both runs preempted; only the flavor differs
    assert rec.stats["preempt_recompute"] > 0
    assert rec.stats["preempt_swap"] == 0
    assert swp.stats["preempt_swap"] > 0
    assert swp.stats["preempt_recompute"] == 0
    assert swp.stats["swap_out_pages"] == swp.stats["swap_in_pages"] > 0
    # resume does NOT re-prefill: the swap run prefills fewer tokens
    assert swp.stats["prefill_tok"] < rec.stats["prefill_tok"]
    swp.pool.check_invariants()


def test_swap_disabled_for_recurrent_state(tiny_random):
    """Hybrid/recurrent archs keep recompute preemption: their state
    rows live outside the page pool, so a KV-only swap would resume
    from the wrong state (kvpool.StatePool docstring)."""
    from repro.models.base import ArchConfig

    cfg = ArchConfig(name="hyb-swap-test", family="hybrid", num_layers=4,
                     d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                     d_ff=128, vocab_size=256, period=("mamba", "attn"),
                     ssm_state=4, dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      page_size=8, host_swap_pages=64)
    assert eng.state_pool is not None
    assert eng._swap_ok is False
    # and a tight run still completes via recompute
    reqs = [Request(uid=i, prompt=np.arange(1, 6, dtype=np.int32),
                    max_new_tokens=8) for i in range(3)]
    res = eng.generate(reqs)
    assert all(len(r.tokens) == 8 for r in res)
    assert eng.stats["preempt_swap"] == 0


def test_stats_surface_through_replica(tiny_random):
    """Satellite 3: the preemption-flavor split and prefix counters ride
    ServeEngine.stats into frontend Replica.stats() — the dict /stats
    serializes."""
    from repro.serve.frontend import Replica

    model, params = tiny_random
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      page_size=8)
    rep = Replica(eng, name="t0")
    try:
        stats = rep.stats()
        for key in ("preempt_swap", "preempt_recompute",
                    "prefix_hit_tokens", "prefill_tok", "cow_copies",
                    "swap_out_pages", "swap_in_pages"):
            assert key in stats, key
    finally:
        rep.close()


# ======================================================================
# interleaving invariants: hypothesis machine + deterministic twin
# ======================================================================
def _refcount_walk(pool, ops):
    """Interpret an op list against the pool and a shadow refcounter;
    check the accounting invariants after every op."""
    shadow = {}                       # page -> refcount

    def live():
        return sorted(shadow)

    for op in ops:
        kind = op % 3
        arg = op // 3
        if kind == 0:                 # alloc 1..3 pages
            n = arg % 3 + 1
            pages = pool.alloc(n)
            if len(shadow) + n <= pool.capacity:
                assert pages is not None
                for p in pages:
                    assert p not in shadow
                    shadow[p] = 1
            else:
                assert pages is None
        elif kind == 1 and shadow:    # share a live page
            p = live()[arg % len(shadow)]
            pool.retain(p)
            shadow[p] += 1
        elif kind == 2 and shadow:    # drop one reference
            p = live()[arg % len(shadow)]
            pool.release([p])
            shadow[p] -= 1
            if shadow[p] == 0:
                del shadow[p]
        pool.check_invariants()
        for p, r in shadow.items():
            assert pool.refcount(p) == r
    assert pool.free_pages == pool.capacity - len(shadow)


@given(st.lists(st.integers(min_value=0, max_value=300), max_size=60))
@settings(max_examples=25, deadline=None)
def test_refcount_state_machine(ops):
    """Hypothesis drives alloc/retain/release interleavings against a
    shadow refcounter (skipped where hypothesis isn't installed — the
    seeded twin below always runs)."""
    cfg = get_config("paper_tiny_lm")
    _refcount_walk(_pool(LM(cfg), num_pages=7), ops)


def test_refcount_state_machine_seeded(tiny_random):
    """Deterministic twin of the hypothesis machine: 400-op seeded
    random walks over alloc/retain/release."""
    model, _ = tiny_random
    for seed in range(3):
        rng = np.random.default_rng(seed)
        ops = rng.integers(0, 300, 400).tolist()
        _refcount_walk(_pool(model, num_pages=7), ops)


def test_engine_random_walk_invariants(tiny_random):
    """The full lifecycle interleaving — admit / prefix-share / CoW /
    retire / swap-preempt — driven by a seeded walk through a REAL
    session on a tight pool, with pool invariants checked after every
    sync interval and final tokens pinned against a roomy-pool run."""
    model, params = tiny_random
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(42)
    shared = np.arange(5, 17, dtype=np.int32)

    def make_requests():
        reqs = []
        for i in range(10):
            if i % 2 == 0:            # shared system prefix + short tail
                prompt = np.concatenate(
                    [shared, rng.integers(1, vocab, 2).astype(np.int32)])
            else:                     # unique prompt
                prompt = rng.integers(1, vocab, int(rng.integers(3, 14))
                                      ).astype(np.int32)
            reqs.append(Request(uid=i, prompt=prompt,
                                max_new_tokens=int(rng.integers(1, 18))))
        return reqs

    reqs = make_requests()
    # roomy reference: no preemption, no sharing pressure
    base = ServeEngine(model, params, max_batch=4, max_len=48,
                       page_size=8, num_pages=33, prefix_cache=False,
                       host_swap_pages=0).generate(reqs)

    eng = ServeEngine(model, params, max_batch=3, max_len=48,
                      page_size=8, num_pages=9, prefix_cache=True,
                      steps_per_sync=3)
    session = eng.session(seed=0)
    it = iter(reqs)
    pending = list(reqs)
    results = {}
    while pending or session.has_work():
        # interleave submissions with steps (arrival jitter)
        for _ in range(int(rng.integers(0, 3))):
            if pending:
                session.submit(pending.pop(0))
        if session.has_work():
            for ev in session.step():
                if ev.finished:
                    results[ev.uid] = ev.result
        eng.pool.check_invariants()
    assert len(results) == len(reqs)
    for r in base:
        np.testing.assert_array_equal(r.tokens, results[r.uid].tokens)
    # the tight pool actually exercised the interesting paths
    assert eng.stats["prefix_hit_tokens"] > 0
    assert (eng.stats["preempt_swap"] + eng.stats["preempt_recompute"]
            + eng.stats["prefix_evictions"]) > 0


# ======================================================================
# 2x4 mesh: shared-prefix serving is bit-identical to unshared
# ======================================================================
def test_shared_prefix_2x4_mesh_parity():
    """Acceptance pin: greedy AND sampled parity with prefix sharing +
    swap on under a real 2x4 mesh (subprocess, as in test_dist.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = """
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import LM
        from repro.dist import use_mesh
        from repro.serve import Request, ServeEngine

        cfg = get_config("paper_tiny_lm")
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        params["unembed"]["head"] = params["unembed"]["head"] * 8.0
        shared = np.arange(5, 17, dtype=np.int32)
        reqs = [Request(uid=i,
                        prompt=np.concatenate(
                            [shared, np.asarray([20 + i, 21 + i],
                                                np.int32)]),
                        max_new_tokens=6)
                for i in range(8)]
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for sampled in (False, True):
            kw = dict(max_batch=4, max_len=64, page_size=8,
                      num_pages=17, steps_per_sync=4)
            if sampled:
                kw.update(temperature=1.0, top_k=5)
            with use_mesh(mesh):
                off = ServeEngine(model, params, prefix_cache=False,
                                  host_swap_pages=0, **kw)
                base = off.generate(reqs, seed=3)
                on = ServeEngine(model, params, prefix_cache=True, **kw)
                got = on.generate(reqs, seed=3)
            assert on.stats["prefix_hit_tokens"] > 0
            for a, b in zip(base, got):
                np.testing.assert_array_equal(a.tokens, b.tokens)
        print("OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
