"""Row-parallel distributed pruning (Remark 4.2) — run with virtual
devices to see the shard_map path produce bit-identical results:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/distributed_prune.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import SparsitySpec, current_ctx, prune_matrix, use_mesh
from repro.core.distributed import hessian_allreduce, prune_matrix_sharded
from repro.core.hessian import HessianAccumulator


def main():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n, m = 64, 128
    key = jax.random.key(0)
    w = jax.random.normal(key, (n, m)) * 0.1

    with use_mesh(mesh):
        ctx = current_ctx()
        print(f"active context: dp={ctx.dp} over {ctx.dp_axes}, "
              f"tp={ctx.tp} over {ctx.tp_axis!r}")

        # 1. data-parallel calibration: each data shard accumulates its
        #    own Hessian over its calibration tokens, one psum merges
        #    them.  The mesh resolves from the context — no mesh arg.
        shards = []
        for i in range(2):
            acc = HessianAccumulator(m)
            acc.update(jax.random.normal(jax.random.fold_in(key, i),
                                         (m, 256 + 64 * i)))
            shards.append(acc)
        h = hessian_allreduce(
            None, jnp.stack([a.h for a in shards]),
            jnp.stack([a.count for a in shards]))
        print(f"merged Hessian from {len(shards)} data shards")

        # 2. row-parallel MRP prune over the `model` axis — zero
        #    collectives inside the layer (rows are independent,
        #    Remark 4.2); again the context supplies the mesh.
        t0 = time.monotonic()
        w_sh, mask_sh = prune_matrix_sharded(w, h, "2:4", method="SM",
                                             blocksize=64)
        t_sh = time.monotonic() - t0

    # 3. single-device reference (outside the context)
    res = prune_matrix(w, h, SparsitySpec.parse("2:4"), method="SM",
                       blocksize=64, row_balanced=True)
    diff = float(jnp.abs(w_sh - res.w).max())
    same_mask = bool(jnp.all(mask_sh == res.mask))
    print(f"sharded prune: {t_sh:.2f}s; |Δw| vs single-device = {diff:.2e}; "
          f"identical mask: {same_mask}")
    print(f"sparsity: {float(jnp.mean(mask_sh)):.3f}")


if __name__ == "__main__":
    main()
