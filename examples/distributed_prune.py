"""Distributed pruning (Remark 4.2 + multi-pod calibration) — run with
virtual devices to see the sharded paths match single-device results:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/distributed_prune.py

Demonstrates the three distributed pieces the PruningEngine composes:
per-pod×data-shard calibration merged with one collective per linear
(``allreduce_calibration``), the row-parallel layer solve, and the
engine's pipelined scheduler driving both.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp

from repro import SparsitySpec, current_ctx, prune_matrix, use_mesh
from repro.core.calibration import CalibrationSet
from repro.core.distributed import (
    allreduce_calibration,
    prune_matrix_sharded,
)


def main():
    print(f"devices: {jax.device_count()}")
    # 2 pods × 2 data shards × 2-way model parallel
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n, m = 64, 128
    key = jax.random.key(0)
    w = jax.random.normal(key, (n, m)) * 0.1

    with use_mesh(mesh):
        ctx = current_ctx()
        print(f"active context: dp={ctx.dp} over {ctx.dp_axes}, "
              f"tp={ctx.tp} over {ctx.tp_axis!r}")

        # 1. multi-pod calibration: every pod×data slice accumulates its
        #    own CalibrationSet over its calibration tokens; the merge is
        #    one hessian_allreduce collective per linear (DCN-friendly —
        #    this is what PruningEngine(calib_shard=...) does per segment)
        sets = []
        for s in range(ctx.dp):
            x = jax.random.normal(jax.random.fold_in(key, s),
                                  (4, 64 + 16 * s, m))
            sets.append(CalibrationSet.from_captures({"wq": x}))
        calib = allreduce_calibration(sets, None, axis_name=ctx.dp_axes)
        h = calib.hessian("wq")
        print(f"merged Hessian from {len(sets)} pod×data shards "
              f"({int(calib.accs['wq'].count)} tokens)")

        # 2. row-parallel MRP prune over the `model` axis — zero
        #    collectives inside the layer (rows are independent,
        #    Remark 4.2); the context supplies the mesh.
        t0 = time.monotonic()
        w_sh, mask_sh = prune_matrix_sharded(w, h, "2:4", method="SM",
                                             blocksize=64)
        t_sh = time.monotonic() - t0

    # 3. single-device reference (outside the context)
    res = prune_matrix(w, h, SparsitySpec.parse("2:4"), method="SM",
                       blocksize=64, row_balanced=True)
    diff = float(jnp.abs(w_sh - res.w).max())
    same_mask = bool(jnp.all(mask_sh == res.mask))
    print(f"sharded prune: {t_sh:.2f}s; |Δw| vs single-device = {diff:.2e}; "
          f"identical mask: {same_mask}")
    print(f"sparsity: {float(jnp.mean(mask_sh)):.3f}")


if __name__ == "__main__":
    main()
