"""Serve a 2:4-pruned model with batched requests through the sparse
(nm_spmm Pallas) weight path, and verify outputs match dense serving.

  PYTHONPATH=src python examples/serve_sparse.py
"""

import time

import numpy as np

from repro.configs import get_config
from repro.core import PruningEngine
from repro.data import DataPipeline, calibration_batches
from repro.models import LM
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.serve import Request, ServeEngine, sparsify_params
from repro.train import TrainConfig, Trainer
from repro.utils.trees import tree_bytes


def main():
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    pipe = DataPipeline(cfg, 16, 64, seed=0)
    trainer = Trainer(
        model, AdamW(lr=warmup_cosine(1e-3, 20, 300)), pipe,
        TrainConfig(total_steps=300, global_batch=16, seq_len=64,
                    ckpt_every=300, out_dir="/tmp/serve_sparse_ckpt",
                    log_every=100))
    params, _, _ = trainer.run()

    print("pruning 2:4 with SM ...")
    calib = calibration_batches(cfg, n_samples=16, seq_len=64, batch=8)
    pruned, _ = PruningEngine(model, "2:4", method="SM",
                              blocksize=64).run(params, calib)
    packed = sparsify_params(pruned, patterns=(r"mlp/(wi|wg|wo)$",))
    print(f"params bytes: dense-pruned={tree_bytes(pruned) / 1e6:.2f}MB")

    reqs = [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=8)
            for i in range(6)]

    for name, ps in (("dense ", pruned), ("sparse", packed)):
        eng = ServeEngine(model, ps, max_batch=6, max_len=48)
        t0 = time.monotonic()
        results = eng.generate(reqs)
        dt = time.monotonic() - t0
        print(f"{name}: {sum(len(r.tokens) for r in results)} tokens "
              f"in {dt:.2f}s; first output: {results[0].tokens.tolist()}")

    d = ServeEngine(model, pruned, max_batch=6, max_len=48).generate(reqs)
    s = ServeEngine(model, packed, max_batch=6, max_len=48).generate(reqs)
    same = all(np.array_equal(a.tokens, b.tokens) for a, b in zip(d, s))
    print(f"sparse == dense outputs: {same}")


if __name__ == "__main__":
    main()
