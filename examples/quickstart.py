"""Quickstart: the paper's MRP pruning on a single linear layer.

Shows the core API in ~40 lines: build a calibration Hessian, prune one
weight matrix with every method, and compare the layer-wise
reconstruction error ‖δw·x‖² — the paper's objective (Eq. 3).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro import HessianAccumulator, SparsitySpec, prune_matrix
from repro.core.pruner import reconstruction_error

key = jax.random.key(0)
n_out, d_in, n_tokens = 256, 512, 4096

# a "layer": weights + calibration activations
w = jax.random.normal(key, (n_out, d_in)) * 0.1
x = jax.random.normal(jax.random.fold_in(key, 1), (d_in, n_tokens))

# streaming Hessian H = mean_t 2·x_t·x_tᵀ (what the engine accumulates
# per linear while the calibration set flows through the model)
acc = HessianAccumulator(d_in)
for i in range(0, n_tokens, 512):
    acc.update(x[:, i:i + 512])
h = acc.finalize()

print(f"layer ({n_out}×{d_in}), {n_tokens} calibration tokens")
for spec in ("0.5", "2:4"):
    print(f"\n=== sparsity {spec} ===")
    methods = (("magnitude", "wanda", "SS", "SM")
               if spec == "0.5" else
               ("magnitude", "wanda", "SS", "SM", "MS", "MM"))
    for method in methods:
        res = prune_matrix(w, h, SparsitySpec.parse(spec),
                           method=method, blocksize=128)
        err = reconstruction_error(w, res.w, h)
        tag = {"SS": "(SparseGPT)", "SM": "(ours — paper's pick)",
               "MM": "(ours, full MRP)"}.get(method, "")
        print(f"  {method:10s} recon ‖δw·x‖² = {err:10.4f}  "
              f"sparsity={res.sparsity:.3f} {tag}")

print("\nLower is better — SM/MM (the paper's MRP solutions) should beat "
      "SS (SparseGPT) which beats the score-only heuristics.")
