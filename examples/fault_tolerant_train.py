"""Fault-tolerant training demo: crash mid-run, resume bit-exactly, with
int8 error-feedback gradient compression enabled.

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import json
import shutil

import jax
import numpy as np

from repro.configs import get_smoke
from repro.data import DataPipeline
from repro.models import LM
from repro.optim import AdamW
from repro.train import TrainConfig, Trainer


def build(out):
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    pipe = DataPipeline(cfg, global_batch=8, seq_len=32, seed=0)
    tc = TrainConfig(total_steps=60, global_batch=8, seq_len=32,
                     ckpt_every=10, out_dir=out, log_every=10,
                     grad_compression=True)
    return Trainer(model, AdamW(lr=1e-3), pipe, tc)


def main():
    out_a, out_b = "/tmp/ft_demo_crash", "/tmp/ft_demo_clean"
    for d in (out_a, out_b):
        shutil.rmtree(d, ignore_errors=True)

    print("run A: train 25/60 steps then 'crash' ...")
    build(out_a).run(max_steps=25)

    print("run A': new process resumes from the last checkpoint ...")
    trainer = build(out_a)
    start, *_ = trainer.restore_or_init()
    print(f"  resumed at step {start} (checkpoint survived the crash)")
    params_a, _, info = trainer.run()
    print(f"  finished: {info['steps']} more steps")

    print("run B: uninterrupted 60 steps ...")
    params_b, _, _ = build(out_b).run()

    diff = max(
        float(np.abs(np.asarray(x, np.float32)
                     - np.asarray(y, np.float32)).max())
        for x, y in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)))
    print(f"max |interrupted - uninterrupted| param diff: {diff:.2e} "
          f"(bit-exact resume: {diff == 0.0})")

    losses = [json.loads(line)["loss"] for line in open(out_b + "/metrics.jsonl")]
    print(f"loss trace (int8 EF-compressed grads): "
          f"{[round(x, 3) for x in losses]}")


if __name__ == "__main__":
    main()
