"""End-to-end driver: train a ~1M-param LM a few hundred steps, prune it
with every method (Algorithm 1 over the whole model), and reproduce the
paper's perplexity ordering.

  PYTHONPATH=src python examples/prune_llm.py [--steps 300]
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.core import PruningEngine
from repro.core.engine import summarize
from repro.data import DataPipeline, calibration_batches
from repro.models import LM
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.train import TrainConfig, Trainer


def eval_ppl(model, params, pipe, n=8):
    tot = cnt = 0.0
    for i in range(n):
        _, m = model.loss_fn(params, pipe.eval_batch(i))
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sparsity", default="2:4")
    args = ap.parse_args()

    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    pipe = DataPipeline(cfg, global_batch=16, seq_len=64, seed=0)

    print(f"training {cfg.name} for {args.steps} steps ...")
    trainer = Trainer(
        model, AdamW(lr=warmup_cosine(1e-3, 20, args.steps)), pipe,
        TrainConfig(total_steps=args.steps, global_batch=16, seq_len=64,
                    ckpt_every=args.steps, out_dir="/tmp/prune_llm_ckpt",
                    log_every=100))
    params, _, _ = trainer.run()
    dense = eval_ppl(model, params, pipe)
    print(f"dense perplexity: {dense:.4f}\n")

    calib = calibration_batches(cfg, n_samples=32, seq_len=64, batch=8)
    methods = (("magnitude", "wanda", "SS", "SM", "MS", "MM")
               if ":" in args.sparsity else
               ("magnitude", "wanda", "SS", "SM"))
    print(f"{'method':12s} {'ppl':>9s} {'Δ vs dense':>10s} "
          f"{'recon error':>12s}")
    for method in methods:
        engine = PruningEngine(model, args.sparsity, method=method,
                               blocksize=64)
        pruned, reports = engine.run(params, calib)
        ppl = eval_ppl(model, pruned, pipe)
        s = summarize(reports)
        tag = {"SS": " ← SparseGPT", "SM": " ← ours (paper's pick)"}.get(
            method, "")
        print(f"{method:12s} {ppl:9.4f} {ppl - dense:+10.4f} "
              f"{s['total_recon_error']:12.3f}{tag}")


if __name__ == "__main__":
    main()
